//! Integration tests for the device-capability scenario engine: dropout /
//! straggler fleets end-to-end through the public API, the all-drop edge,
//! compatibility of profile sampling with the legacy binary split, and
//! the checkpoint/catch-up subsystem's bit-exact rejoin guarantee.

use std::sync::Arc;

use zowarmup::config::{FedConfig, Scale};
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::Source;
use zowarmup::data::synthetic::{train_test, SynthKind};
use zowarmup::fed::server::{assign_resources, shards_from_partition, Federation};
use zowarmup::model::backend::{LinearBackend, ModelBackend};
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn probe() -> LinearBackend {
    LinearBackend::pooled(32 * 32 * 3, 2, 10, 32)
}

fn setup(cfg: &FedConfig) -> (Vec<zowarmup::data::loader::ClientData>, Source) {
    let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
    let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
    let src = Source::Image(Arc::new(train));
    (
        shards_from_partition(&src, &part),
        Source::Image(Arc::new(test)),
    )
}

#[test]
fn all_drop_zo_round_logs_zero_signal_charges_no_uplink_keeps_params() {
    // satellite: a ZO round where every sampled client misses the
    // deadline must log the finite 0.0 train signal, charge zero uplink,
    // and leave params untouched. The single tier is so slow that even
    // the seed-issue download blows the deadline.
    let mut cfg = Scale::Smoke.fed();
    cfg.pivot = 0; // ZO from round 0
    cfg.rounds_total = 1;
    cfg.scenario = Scenario::load(
        r#"{"name": "all-drop", "deadline_ms": 0.5,
            "tiers": [{"frac": 1.0, "mem": "zo",
                       "up_mbps": 0.001, "down_mbps": 0.001, "compute": 0.001}]}"#,
    )
    .unwrap();
    let (shards, test) = setup(&cfg);
    let be = probe();
    let init = ParamVec::zeros(be.dim());
    let mut fed = Federation::new(cfg.clone(), &be, shards, test, init.clone()).unwrap();
    fed.run().unwrap();

    let r = &fed.log.rounds[0];
    assert_eq!(r.train_loss, 0.0, "all-drop round must log the finite 0.0 signal");
    assert!(r.train_loss.is_finite());
    assert_eq!(r.dropped, cfg.sample_zo, "every sampled client dropped");
    assert_eq!(r.bytes_up, 0, "nothing survived to upload");
    assert!(
        r.bytes_down < (cfg.sample_zo * cfg.zo.s_seeds * 8) as u64,
        "only partial seed-issue downloads may be charged"
    );
    assert_eq!(fed.global, init, "no surviving contribution may move params");
}

#[test]
fn straggler_fleet_end_to_end_is_bit_identical_across_workers() {
    // acceptance: `--scenario stragglers` runs a dropout/straggler fleet
    // end-to-end with bit-identical results across worker counts and a
    // byte-accurate ledger (partial transmissions included)
    let run = |threads: usize| {
        let mut cfg = Scale::Smoke.fed();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg.threads = threads;
        cfg.scenario = Scenario::preset("stragglers").unwrap();
        let (shards, test) = setup(&cfg);
        let be = probe();
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        (fed.global.clone(), fed.log.clone(), fed.ledger.clone())
    };
    let (g1, log1, led1) = run(1);
    let (g2, _, led2) = run(2);
    let (g4, log4, led4) = run(4);
    assert_eq!(g1, g2);
    assert_eq!(g1, g4);
    assert_eq!((led1.up_total, led1.down_total), (led2.up_total, led2.down_total));
    assert_eq!((led1.up_total, led1.down_total), (led4.up_total, led4.down_total));
    for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!((a.bytes_up, a.bytes_down, a.dropped), (b.bytes_up, b.bytes_down, b.dropped));
    }
    assert!(
        log1.total_dropped() > 0,
        "the straggler fleet should drop clients mid-round"
    );
    assert!(g1.is_finite());
}

#[test]
fn scenario_loads_from_json_file_and_drives_a_run() {
    // the `train --scenario file.json` path: write a spec, load by path,
    // run a short federation under it
    let path = std::env::temp_dir().join("zow_scenario_test.json");
    std::fs::write(
        &path,
        r#"{"name": "file-fleet", "deadline_ms": 0,
            "tiers": [
              {"name": "fast", "frac": 0.5, "mem": "backprop",
               "up_mbps": 100, "down_mbps": 100, "compute": 4.0},
              {"name": "slow", "frac": 0.5, "mem": "zo",
               "up_mbps": 4, "down_mbps": 8, "drop_rate": 0.3}
            ]}"#,
    )
    .unwrap();
    let mut cfg = Scale::Smoke.fed();
    cfg.scenario = Scenario::load(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.scenario.name(), "file-fleet");
    cfg.lr_client_warm = 0.06;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
    let (shards, test) = setup(&cfg);
    let be = probe();
    let mut fed =
        Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
    fed.run().unwrap();
    assert!(fed.log.final_accuracy().is_finite());
    assert!(fed.global.is_finite());
    std::fs::remove_file(path).ok();
}

#[test]
fn rejoin_after_drop_reconstructs_bit_identical_to_continuous() {
    // acceptance: a client that drops at round r and rejoins at round
    // r + k reconstructs the global parameters — snapshot + tail replay
    // through the same sharded fused pass — bit-identical to a client
    // that never left (which simply holds the live global), at every
    // worker count {1, 2, 4}. The churn fleet supplies real drop/rejoin/
    // late-join events; ckpt_every = 2 exercises compaction mid-run.
    let mut finals: Vec<(ParamVec, u64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut cfg = Scale::Smoke.fed();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg.threads = threads;
        cfg.ckpt_every = 2;
        cfg.scenario = Scenario::preset("churn").unwrap();
        let (shards, test) = setup(&cfg);
        let be = probe();
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg.clone(), &be, shards, test, init).unwrap();
        // the live global *entering* each round — what a continuously
        // participating client holds at that point
        let mut entering: Vec<ParamVec> = Vec::new();
        while fed.round < cfg.rounds_total {
            entering.push(fed.global.clone());
            fed.step().unwrap();
        }
        entering.push(fed.global.clone());

        // every round the store can still serve must reconstruct to the
        // exact live state (base_round moved forward by compaction)
        let base = fed.ckpt.base_round();
        let top = base + fed.ckpt.tail_rounds();
        assert!(top == cfg.rounds_total, "store must cover the full run");
        for target in base..=top {
            let rebuilt = fed
                .ckpt
                .reconstruct(target, cfg.zo.tau, cfg.zo.dist, threads, cfg.zo.kernel)
                .unwrap();
            assert_eq!(
                rebuilt, entering[target],
                "rejoin reconstruction diverged at round {target} (threads {threads})"
            );
        }
        // churn + checkpointing must actually charge catch-up downlink
        assert!(fed.ledger.catch_up_down_total > 0);
        assert!(fed.log.total_dropped() > 0, "churn fleet must miss rounds");
        finals.push((fed.global.clone(), fed.ledger.catch_up_down_total));
    }
    // and the whole thing is worker-count invariant, catch-up included
    for f in &finals[1..] {
        assert_eq!(f.0, finals[0].0, "weights must not depend on threads");
        assert_eq!(f.1, finals[0].1, "catch-up bytes must not depend on threads");
    }
}

#[test]
fn rejoin_with_heterogeneous_s_reconstructs_bit_identical_to_continuous() {
    // acceptance: the bit-exact rejoin guarantee survives heterogeneous
    // per-client probe budgets — adaptive-S items (variable S_j, guarded
    // weights) flow through the same fused (seed, coeff) artifact, so
    // snapshot + tail replay still lands exactly on the live state at
    // every worker count {1, 2, 4}.
    let mut finals: Vec<(ParamVec, u64, u64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut cfg = Scale::Smoke.fed();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg.threads = threads;
        cfg.ckpt_every = 2;
        cfg.zo.adaptive_s = true;
        cfg.zo.s_min = 1;
        cfg.zo.s_max = 8;
        cfg.scenario = Scenario::preset("churn").unwrap();
        let (shards, test) = setup(&cfg);
        let be = probe();
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg.clone(), &be, shards, test, init).unwrap();
        let mut entering: Vec<ParamVec> = Vec::new();
        while fed.round < cfg.rounds_total {
            entering.push(fed.global.clone());
            fed.step().unwrap();
        }
        entering.push(fed.global.clone());
        // heterogeneous budgets must actually occur: past round 8 the
        // late tier has deterministically joined, so the planner sees
        // both the 4x-faster anchors and the slow late/flaky tiers
        let all: Vec<usize> = (0..cfg.clients).collect();
        let counts = fed.planned_seed_counts(&all);
        let distinct: std::collections::BTreeSet<usize> =
            counts.iter().map(|&(_, s)| s).collect();
        assert!(
            distinct.len() > 1,
            "churn + adaptive must plan heterogeneous budgets: {counts:?}"
        );
        let base = fed.ckpt.base_round();
        let top = base + fed.ckpt.tail_rounds();
        assert_eq!(top, cfg.rounds_total, "store must cover the full run");
        for target in base..=top {
            let rebuilt = fed
                .ckpt
                .reconstruct(target, cfg.zo.tau, cfg.zo.dist, threads, cfg.zo.kernel)
                .unwrap();
            assert_eq!(
                rebuilt, entering[target],
                "heterogeneous-S rejoin diverged at round {target} (threads {threads})"
            );
        }
        assert!(fed.ledger.catch_up_down_total > 0);
        assert!(fed.ledger.seeds_total > 0);
        assert!(fed.global.is_finite());
        finals.push((
            fed.global.clone(),
            fed.ledger.catch_up_down_total,
            fed.ledger.seeds_total,
        ));
    }
    for f in &finals[1..] {
        assert_eq!(f.0, finals[0].0, "weights must not depend on threads");
        assert_eq!(f.1, finals[0].1, "catch-up bytes must not depend on threads");
        assert_eq!(f.2, finals[0].2, "issued seeds must not depend on threads");
    }
}

#[test]
fn adaptive_s_off_leaves_existing_fixtures_bit_identical() {
    // acceptance: the new knobs at their defaults change NOTHING — a run
    // with the fields explicitly forced to the documented defaults equals
    // the plain default run bit for bit (weights, logs, ledgers, and the
    // new accounting columns).
    let run = |touch: bool| {
        let mut cfg = Scale::Smoke.fed();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg.scenario = Scenario::preset("stragglers").unwrap();
        if touch {
            cfg.zo.adaptive_s = false;
            cfg.zo.s_min = 1;
            cfg.zo.s_max = 16;
            cfg.zo.guard = zowarmup::config::VarianceGuard::Off;
        }
        let (shards, test) = setup(&cfg);
        let be = probe();
        let mut fed =
            Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
        fed.run().unwrap();
        (fed.global.clone(), fed.log.clone(), fed.ledger.clone())
    };
    let (g_a, log_a, led_a) = run(false);
    let (g_b, log_b, led_b) = run(true);
    assert_eq!(g_a, g_b);
    assert_eq!((led_a.up_total, led_a.down_total), (led_b.up_total, led_b.down_total));
    assert_eq!(led_a.seeds_total, led_b.seeds_total);
    for (a, b) in log_a.rounds.iter().zip(&log_b.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.seeds_issued, b.seeds_issued);
        assert_eq!(a.eff_var.to_bits(), b.eff_var.to_bits());
    }
}

#[test]
fn checkpointing_is_observational_without_deadlines() {
    // with no round deadline, the catch-up download can never change who
    // survives — so enabling checkpointing changes ONLY the byte
    // accounting: weights and train signals are bit-identical to the
    // disabled run, and the default (disabled) run charges nothing.
    let run = |ckpt_every: usize| {
        let mut cfg = Scale::Smoke.fed();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg.ckpt_every = ckpt_every;
        cfg.scenario = Scenario::preset("churn").unwrap();
        assert_eq!(cfg.scenario.deadline_ms(), 0.0);
        let (shards, test) = setup(&cfg);
        let be = probe();
        let mut fed =
            Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
        fed.run().unwrap();
        (fed.global.clone(), fed.log.clone(), fed.ledger.clone())
    };
    let (g_off, log_off, led_off) = run(0);
    let (g_on, log_on, led_on) = run(3);
    assert_eq!(g_off, g_on, "checkpointing must not move the weights");
    for (a, b) in log_off.rounds.iter().zip(&log_on.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.dropped, b.dropped);
    }
    assert_eq!(led_off.catch_up_down_total, 0, "disabled ⇒ free rejoin");
    assert_eq!(log_off.total_catch_up_down(), 0);
    assert!(led_on.catch_up_down_total > 0, "enabled ⇒ honest catch-up charge");
    assert_eq!(log_on.total_catch_up_down(), led_on.catch_up_down_total);
    assert!(
        led_on.down_total >= led_off.down_total,
        "catch-up only ever adds downlink"
    );
}

#[test]
fn ten_million_client_fleet_round_allocates_o_sampled_not_o_n() {
    // acceptance: `--scenario fleet --clients 10000000` completes rounds
    // without allocating any O(N) per-client vector — the population
    // descriptor stays O(1), the sync ledger only ever holds entries for
    // clients that actually participated, and results are bit-identical
    // across worker counts.
    let run = |threads: usize| {
        let mut cfg = Scale::Smoke.fed();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg.clients = 10_000_000;
        cfg.sample_zo = 16;
        cfg.sample_warm = 4;
        cfg.rounds_total = 6;
        cfg.pivot = 2;
        cfg.threads = threads;
        cfg.scenario = Scenario::preset("fleet").unwrap();
        assert!(cfg.lazy_population(), "Auto must resolve lazy at 1e7 clients");
        let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
        let be = probe();
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new_lazy(
            cfg.clone(),
            &be,
            Source::Image(Arc::new(train)),
            test,
            init,
        )
        .unwrap();
        fed.run().unwrap();
        assert!(fed.pop.is_lazy());
        // the sparse-ledger / lazy-profile acceptance assertions: no O(N)
        // per-client vector exists anywhere in the federation state
        let state = fed.pop.approx_state_bytes();
        assert!(
            state < 4096,
            "population layer holds {state} B for 10^7 clients — something materialized"
        );
        let max_participants = cfg.rounds_total * cfg.sample_zo.max(cfg.sample_warm);
        assert!(
            fed.synced.deviated() <= max_participants,
            "sync ledger holds {} entries for at most {max_participants} participants",
            fed.synced.deviated()
        );
        // an untouched client reads the population default without allocating
        assert_eq!(fed.synced.get(9_999_998), 0);
        assert!(fed.global.is_finite());
        (fed.global.clone(), fed.log.clone(), fed.ledger.clone())
    };
    let (g1, log1, led1) = run(1);
    assert!(log1.rounds.iter().any(|r| r.train_loss != 0.0));
    // and the fleet path keeps the engine's determinism contract
    let (g4, log4, led4) = run(4);
    assert_eq!(g1, g4, "weights must not depend on threads");
    assert_eq!(
        (led1.up_total, led1.down_total),
        (led4.up_total, led4.down_total)
    );
    for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!((a.bytes_up, a.bytes_down, a.dropped), (b.bytes_up, b.bytes_down, b.dropped));
    }
}

#[test]
fn default_scenario_reproduces_legacy_assignment_and_results() {
    // acceptance: assign_resources-compatible configs reproduce the
    // seed's exact High/Low assignment through profile sampling
    for seed in [0u64, 1, 42] {
        let mut cfg = Scale::Smoke.fed();
        cfg.seed = seed;
        let (shards, test) = setup(&cfg);
        let be = probe();
        let fed =
            Federation::new(cfg.clone(), &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
        let legacy = assign_resources(cfg.clients, cfg.hi_count(), seed);
        let derived: Vec<_> = (0..cfg.clients)
            .map(|cid| fed.pop.resource(cid, &fed.cost))
            .collect();
        assert_eq!(derived, legacy, "seed {seed}");
    }
    // and a default-scenario run never drops anyone
    let mut cfg = Scale::Smoke.fed();
    cfg.lr_client_warm = 0.06;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
    let (shards, test) = setup(&cfg);
    let be = probe();
    let mut fed =
        Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
    fed.run().unwrap();
    assert_eq!(fed.log.total_dropped(), 0, "binary scenario has no drop paths");
}
