//! Bench: the ZO-phase hot loop — seeded perturbation streams and the
//! ZOUPDATE axpy reconstruction. This is the L3 path that runs once per
//! (seed, ΔL) pair per round on every participant, so its throughput caps
//! feasible model size (§Perf L3).

use std::time::Duration;

use zowarmup::ckpt::CheckpointStore;
use zowarmup::config::{KernelKind, VarianceGuard, ZoConfig};
use zowarmup::model::params::ParamVec;
use zowarmup::util::bench::{black_box, quick, Bench};
use zowarmup::util::rng::{Distribution, PerturbStream, Xoshiro256};
use zowarmup::zo::{apply_zo_update, zo_update_items, ZoContribution};

fn main() {
    let mut b = Bench::new("zo_core");
    // quick mode (ZOWARMUP_BENCH_QUICK=1, the CI bench-smoke step) skips
    // the ResNet-scale d=11M cases so the suite finishes in seconds
    let full = !quick();

    // raw stream generation
    let stream_dims: &[usize] = if full {
        &[44_370, 175_258, 11_173_962]
    } else {
        &[44_370, 175_258]
    };
    for &d in stream_dims {
        let mut out = vec![0.0f32; d];
        b.iter_with_items(&format!("rademacher_stream d={d}"), d as f64, || {
            let mut s = PerturbStream::new(7, 0.75, Distribution::Rademacher);
            s.fill(&mut out);
            black_box(&out);
        });
    }
    {
        let d = 175_258;
        let mut out = vec![0.0f32; d];
        b.iter_with_items(&format!("gaussian_stream d={d}"), d as f64, || {
            let mut s = PerturbStream::new(7, 0.75, Distribution::Gaussian);
            s.fill(&mut out);
            black_box(&out);
        });
    }

    // the fused perturb-axpy (the protocol's unit of work)
    let axpy_dims: &[usize] = if full { &[175_258, 11_173_962] } else { &[175_258] };
    for &d in axpy_dims {
        let mut w = ParamVec(vec![0.1f32; d]);
        b.iter_with_items(&format!("perturb_axpy d={d}"), d as f64, || {
            w.perturb_axpy(13, 0.75, Distribution::Rademacher, 1e-4);
            black_box(&w.0[0]);
        });
    }

    // one full ZOUPDATE: Q=10 clients x S=3 seeds at ResNet18 scale
    {
        let d = 1_000_000;
        let mut global = ParamVec(vec![0.1f32; d]);
        let cfg = ZoConfig::default();
        let contribs: Vec<ZoContribution> = (0..10)
            .map(|c| ZoContribution {
                client: c,
                seeds: vec![c as u64 * 3, c as u64 * 3 + 1, c as u64 * 3 + 2],
                delta_l: vec![0.01, -0.02, 0.005],
                n_samples: 100,
                s_block: 3,
            })
            .collect();
        b.iter_with_items("apply_zo_update d=1M Q=10 S=3", (d * 30) as f64, || {
            apply_zo_update(&mut global, &contribs, &cfg, 1.0, 0.01);
            black_box(&global.0[0]);
        });
        // sharded across workers (bit-identical results; see model::params)
        for workers in [2usize, 4] {
            let mut g = ParamVec(vec![0.1f32; d]);
            b.iter_with_items(
                &format!("apply_zo_update_sharded d=1M Q=10 S=3 w={workers}"),
                (d * 30) as f64,
                || {
                    zowarmup::zo::apply_zo_update_sharded(
                        &mut g, &contribs, &cfg, 1.0, 0.01, workers,
                    );
                    black_box(&g.0[0]);
                },
            );
        }
        // the item-fold itself (no weight pass): the variance guards add
        // per-contribution statistics on top of the plain fold —
        // negligible next to the O(d) axpy, measured here to keep it so.
        // Heterogeneous S_j blocks (adaptive-S shape) ride the same path.
        let hetero: Vec<ZoContribution> = (0..10)
            .map(|c| {
                let s = 2 + (c % 5); // S_j in 2..=6
                ZoContribution {
                    client: c,
                    seeds: (0..s as u64).map(|i| c as u64 * 100 + i).collect(),
                    delta_l: (0..s).map(|i| 0.01 * (i as f64 - 2.0)).collect(),
                    n_samples: 100,
                    s_block: s,
                }
            })
            .collect();
        for guard in [VarianceGuard::Off, VarianceGuard::InvVar, VarianceGuard::Clip] {
            let mut gcfg = cfg;
            gcfg.guard = guard;
            b.iter_with_items(
                &format!("zo_update_items hetero Q=10 guard={}", guard.as_str()),
                40.0,
                || {
                    black_box(zo_update_items(&hetero, &gcfg, 1.0, 0.01));
                },
            );
        }
    }

    // the kernel matchup: one full ZOUPDATE (Q=10 x S=3) at ResNet18
    // scale d=11M, scalar vs lane-split kernel, sequential and 4-way
    // sharded. These four rows are the §Perf speedup evidence for the
    // lanes kernel and the CI gate requires them by name (--require),
    // so they run in quick mode too — at a floor-of-one iteration
    // budget to keep the bench-smoke step fast.
    {
        let d = 11_173_962;
        let contribs: Vec<ZoContribution> = (0..10)
            .map(|c| ZoContribution {
                client: c,
                seeds: vec![c as u64 * 3, c as u64 * 3 + 1, c as u64 * 3 + 2],
                delta_l: vec![0.01, -0.02, 0.005],
                n_samples: 100,
                s_block: 3,
            })
            .collect();
        let saved = (b.min_time, b.min_iters, b.warmup_iters);
        if !full {
            b.min_time = Duration::from_millis(0);
            b.min_iters = 1;
            b.warmup_iters = 0;
        }
        for kernel in [KernelKind::Scalar, KernelKind::Lanes] {
            let kcfg = ZoConfig { kernel, ..ZoConfig::default() };
            for workers in [1usize, 4] {
                let mut g = ParamVec(vec![0.1f32; d]);
                b.iter_with_items(
                    &format!("apply_zo_update d=11M kernel={} w={workers}", kernel.as_str()),
                    (d * 30) as f64,
                    || {
                        zowarmup::zo::apply_zo_update_sharded(
                            &mut g, &contribs, &kcfg, 1.0, 0.01, workers,
                        );
                        black_box(&g.0[0]);
                    },
                );
            }
        }
        (b.min_time, b.min_iters, b.warmup_iters) = saved;
    }

    // the fused single-pass variant actually used by apply_zo_update
    {
        let d = 1_000_000;
        let mut w = vec![0.1f32; d];
        let items: Vec<(u64, f32)> = (0..30).map(|i| (i as u64, 1e-4)).collect();
        b.iter_with_items(
            "perturb_axpy_many d=1M x30 (fused pass)",
            (d * 30) as f64,
            || {
                zowarmup::model::params::perturb_axpy_many(
                    &mut w,
                    &items,
                    0.75,
                    Distribution::Rademacher,
                );
                black_box(&w[0]);
            },
        );
    }

    // parallel vs sequential fused pass: the sharded variant splits the
    // weight vector into 64-aligned chunks with bit-exact stream
    // fast-forward (ZOUPDATE at ResNet scale is memory-bound single-core)
    for workers in if full { &[1usize, 2, 4, 8][..] } else { &[1usize, 2][..] } {
        let &workers = workers;
        let d = if full { 11_173_962 } else { 1_000_000 };
        let mut w = vec![0.1f32; d];
        let items: Vec<(u64, f32)> = (0..30).map(|i| (i as u64, 1e-4)).collect();
        b.iter_with_items(
            &format!("perturb_axpy_many_sharded d={d} x30 w={workers}"),
            (d * 30) as f64,
            || {
                zowarmup::model::params::perturb_axpy_many_sharded(
                    &mut w,
                    &items,
                    0.75,
                    Distribution::Rademacher,
                    workers,
                );
                black_box(&w[0]);
            },
        );
    }

    // checkpoint catch-up: a late joiner reconstructing the current model
    // from snapshot + tail replay at ResNet18 scale. Each tail round
    // carries Q·S = 30 (seed, coeff) items; the replay is the same
    // sharded fused pass the live server uses, so throughput here is the
    // rejoin latency bound (item-applications/s = d · items · rounds).
    {
        let d = if full { 11_173_962 } else { 1_000_000 };
        let init = ParamVec(vec![0.1f32; d]);
        for &rounds in if full { &[4usize, 16][..] } else { &[4usize][..] } {
            let mut store = CheckpointStore::new(rounds + 1, &init); // no compaction
            let mut live = init.clone();
            for r in 0..rounds {
                let items: Vec<(u64, f32)> =
                    (0..30).map(|i| ((r * 30 + i) as u64, 1e-4)).collect();
                zowarmup::model::params::perturb_axpy_many_sharded(
                    &mut live.0,
                    &items,
                    0.75,
                    Distribution::Rademacher,
                    1,
                );
                store.record_seed_round(r, items, &live);
            }
            for &workers in &[1usize, 4] {
                b.iter_with_items(
                    &format!("ckpt_tail_replay d={d} rounds={rounds} w={workers}"),
                    (d * 30 * rounds) as f64,
                    || {
                        let p = store
                            .reconstruct(
                                rounds,
                                0.75,
                                Distribution::Rademacher,
                                workers,
                                KernelKind::Scalar,
                            )
                            .unwrap();
                        black_box(&p.0[0]);
                    },
                );
            }
        }
    }

    // xoshiro baseline for context
    {
        let mut rng = Xoshiro256::seed_from(3);
        b.iter_with_items("xoshiro_u64 x1M", 1e6, || {
            let mut acc = 0u64;
            for _ in 0..1_000_000 {
                acc = acc.wrapping_add(rng.next_u64());
            }
            black_box(acc);
        });
    }

    // stream fast-forward: the O(log n) GF(2) jump vs the draw loop at
    // the last-shard-worker offset PR 1 flagged (d=11M ⇒ ~175k draws per
    // stream; ~4.6M across 30 streams). The jump makes setup offset-
    // independent.
    {
        let n: u64 = 4_600_000;
        b.iter("xoshiro_discard jump n=4.6M", || {
            let mut rng = Xoshiro256::seed_from(9);
            rng.discard(n);
            black_box(rng.next_u64());
        });
        b.iter("xoshiro_discard loop n=100k (pre-jump path shape)", || {
            let mut rng = Xoshiro256::seed_from(9);
            for _ in 0..100_000u64 {
                rng.next_u64();
            }
            black_box(rng.next_u64());
        });
    }

    b.report();
    if let Err(e) = b.write_json("runs/BENCH_zo_core.json") {
        eprintln!("bench json: {e}");
    }
}
