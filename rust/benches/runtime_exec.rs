//! Bench: PJRT artifact execution latency — the L2/L1 unit costs the
//! coordinator schedules around (§Perf L2). Requires `make artifacts`.
//!
//! Measures, per model: fwd_loss (the ZO-phase unit, 2 per seed),
//! sgd_step (the warm-phase unit), and the fused graph-mode zo_delta
//! (1 exec = both SPSA sides + in-graph perturbation) vs the host path
//! (2 fwd execs + 2 host perturbs) at equal semantics.

use std::sync::Arc;

use zowarmup::data::loader::{ClientData, Source};
use zowarmup::data::synthetic::{generate, GenConfig, SynthKind};
use zowarmup::data::lm;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::manifest::Manifest;
use zowarmup::model::params::ParamVec;
use zowarmup::runtime::Engine;
use zowarmup::util::bench::{black_box, Bench};
use zowarmup::util::rng::Distribution;

fn main() -> anyhow::Result<()> {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime_exec bench: {e}");
            return Ok(());
        }
    };
    let engine = Engine::cpu()?;
    let mut b = Bench::slow("runtime_exec");
    b.min_iters = 5;

    for model in ["cnn10", "cnn10_half", "vit10"] {
        let backend = engine.backend(&manifest, model)?;
        let entry = manifest.model(model)?.clone();
        let data = generate(SynthKind::Synth10, entry.batch, GenConfig::default());
        let cd = ClientData {
            source: Source::Image(Arc::new(data)),
            indices: (0..entry.batch).collect(),
        };
        let batch = cd.chunks(entry.batch).pop().unwrap();
        let mut params = ParamVec::he_init(&entry, 0);
        let items = entry.batch as f64;

        b.iter_with_items(&format!("{model} fwd_loss B={}", entry.batch), items, || {
            black_box(backend.fwd_loss(&params, &batch).unwrap());
        });
        b.iter_with_items(&format!("{model} sgd_step B={}", entry.batch), items, || {
            black_box(backend.sgd_step(&mut params, &batch, 1e-4).unwrap());
        });
        b.iter_with_items(&format!("{model} zo_delta host (2 fwd + 2 axpy)"), items, || {
            black_box(
                backend
                    .zo_delta(&params, &batch, 42, 1e-4, 0.75, Distribution::Rademacher)
                    .unwrap(),
            );
        });
        b.iter_with_items(&format!("{model} zo_delta fused (1 exec)"), items, || {
            black_box(backend.zo_delta_fused(&params, &batch, 42, 7.5e-5).unwrap());
        });
    }

    // the LM path (fig5's workhorse)
    {
        let backend = engine.backend(&manifest, "lm")?;
        let entry = manifest.model("lm")?.clone();
        let data = lm::generate(64, 64, entry.batch, 0);
        let cd = ClientData {
            source: Source::Lm(Arc::new(data)),
            indices: (0..entry.batch).collect(),
        };
        let batch = cd.chunks(entry.batch).pop().unwrap();
        let params = ParamVec::he_init(&entry, 0);
        b.iter_with_items("lm fwd_loss B=16", entry.batch as f64, || {
            black_box(backend.fwd_loss(&params, &batch).unwrap());
        });
    }

    b.report();
    Ok(())
}
