//! Bench: coordinator primitives — aggregation, HeteroFL slicing/scatter,
//! Dirichlet partitioning, data generation, batch assembly (§Perf L3).

use std::sync::Arc;
use std::time::Duration;

use zowarmup::baselines::heterofl::{heterofl_aggregate, SliceMap};
use zowarmup::config::{FedConfig, KernelKind, ServerOpt};
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::{ClientData, Source};
use zowarmup::data::synthetic::{generate, train_test, GenConfig, SynthKind};
use zowarmup::fed::aggregate::{weighted_average, ServerOptState};
use zowarmup::fed::server::{shards_from_partition, Federation};
use zowarmup::model::backend::LinearBackend;
use zowarmup::model::params::{perturb_axpy_many_sharded_kernel, ParamVec};
use zowarmup::util::bench::{black_box, quick, Bench};
use zowarmup::util::rng::Distribution;

fn main() {
    let mut b = Bench::new("fed_primitives");

    // weighted average at warm-round shape (P=10 clients, d=175k)
    {
        let d = 175_258;
        let updates: Vec<(ParamVec, f64)> =
            (0..10).map(|i| (ParamVec(vec![i as f32; d]), 100.0)).collect();
        b.iter_with_items("weighted_average P=10 d=175k", (d * 10) as f64, || {
            black_box(weighted_average(&updates));
        });
    }

    // the server-side ZOUPDATE fold at ResNet18 scale d=11M: the raw
    // (seed, coeff) sweep the coordinator runs once per round, scalar vs
    // lane-split kernel. Required by name in the CI gate (--require), so
    // the rows are emitted in quick mode too — at a floor-of-one
    // iteration budget to keep the bench-smoke step fast.
    {
        let d = 11_173_962;
        let items: Vec<(u64, f32)> = (0..30).map(|i| (i as u64, 1e-4)).collect();
        let saved = (b.min_time, b.min_iters, b.warmup_iters);
        if quick() {
            b.min_time = Duration::from_millis(0);
            b.min_iters = 1;
            b.warmup_iters = 0;
        }
        for kernel in [KernelKind::Scalar, KernelKind::Lanes] {
            for workers in [1usize, 4] {
                let mut w = vec![0.1f32; d];
                b.iter_with_items(
                    &format!("zo_fold d=11M x30 kernel={} w={workers}", kernel.as_str()),
                    (d * 30) as f64,
                    || {
                        perturb_axpy_many_sharded_kernel(
                            &mut w,
                            &items,
                            0.75,
                            Distribution::Rademacher,
                            workers,
                            kernel,
                        );
                        black_box(&w[0]);
                    },
                );
            }
        }
        (b.min_time, b.min_iters, b.warmup_iters) = saved;
    }

    // server optimizers
    {
        let d = 175_258;
        let delta = ParamVec(vec![0.01f32; d]);
        let mut g_sgd = ParamVec(vec![0.0f32; d]);
        let mut sgd = ServerOptState::new(ServerOpt::Sgd, d);
        b.iter_with_items("server_opt sgd d=175k", d as f64, || {
            sgd.apply(&mut g_sgd, &delta, 1.0);
            black_box(&g_sgd.0[0]);
        });
        let mut g_adam = ParamVec(vec![0.0f32; d]);
        let mut adam = ServerOptState::new(ServerOpt::adam(), d);
        b.iter_with_items("server_opt adam d=175k", d as f64, || {
            adam.apply(&mut g_adam, &delta, 0.001);
            black_box(&g_adam.0[0]);
        });
    }

    // HeteroFL slice + aggregate at linear-probe shape
    {
        let classes = 10;
        let features = 3072;
        let fh = features / 2;
        let map = SliceMap::from_shape_pairs(
            &[
                (vec![classes, features], 0, vec![classes, fh], 0),
                (vec![classes], classes * features, vec![classes], classes * fh),
            ],
            classes * features + classes,
            classes * fh + classes,
        )
        .unwrap();
        let global = ParamVec(vec![0.5f32; map.full_dim]);
        b.iter_with_items("heterofl slice d=30k", map.half_dim() as f64, || {
            black_box(map.slice(&global));
        });
        let mut g = global.clone();
        let fulls: Vec<(ParamVec, f64)> =
            (0..3).map(|_| (ParamVec(vec![1.0; map.full_dim]), 100.0)).collect();
        let halves: Vec<(ParamVec, f64)> =
            (0..7).map(|_| (ParamVec(vec![2.0; map.half_dim()]), 100.0)).collect();
        b.iter_with_items("heterofl aggregate 3 full + 7 half", map.full_dim as f64, || {
            heterofl_aggregate(&mut g, &fulls, &halves, &map);
            black_box(&g.0[0]);
        });
    }

    // data pipeline
    {
        b.iter_with_items("synth10 generate n=1000", 1000.0, || {
            black_box(generate(SynthKind::Synth10, 1000, GenConfig::default()));
        });
        let data = generate(SynthKind::Synth10, 2000, GenConfig::default());
        b.iter("dirichlet_split K=50 alpha=0.1", || {
            black_box(dirichlet_split(&data, 50, 0.1, 0));
        });
        let cd = ClientData {
            source: Source::Image(Arc::new(data.clone())),
            indices: (0..512).collect(),
        };
        b.iter_with_items("batch assembly 512 samples @B=64", 512.0, || {
            black_box(cd.chunks(64));
        });
    }

    // parallel vs sequential round execution: identical results for every
    // worker count (fed::server threading model); on multi-core hosts the
    // fan-out over sampled clients is the round's wall-clock win
    {
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.clients = 8;
        cfg.sample_zo = 8;
        cfg.sample_warm = 4;
        cfg.hi_frac = 0.5;
        cfg.pivot = 0;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        let (train, test) = train_test(SynthKind::Synth10, 1600, 100, 0);
        let part = dirichlet_split(&train, cfg.clients, 0.5, 0);
        let src = Source::Image(Arc::new(train));
        let test_src = Source::Image(Arc::new(test));
        let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
        for threads in [1usize, 2, 4] {
            let mut c = cfg.clone();
            c.threads = threads;
            let shards = shards_from_partition(&src, &part);
            let init = ParamVec::zeros(be.dim());
            let mut fed =
                Federation::new(c, &be, shards, test_src.clone(), init).unwrap();
            b.iter(&format!("zo_round Q=8 (linear probe) threads={threads}"), || {
                black_box(fed.zo_round().unwrap());
            });
        }
        for threads in [1usize, 4] {
            let mut c = cfg.clone();
            c.threads = threads;
            c.pivot = c.rounds_total; // warm phase only
            let shards = shards_from_partition(&src, &part);
            let init = ParamVec::zeros(be.dim());
            let mut fed =
                Federation::new(c, &be, shards, test_src.clone(), init).unwrap();
            b.iter(&format!("warm_round P=4 (linear probe) threads={threads}"), || {
                black_box(fed.warm_round().unwrap());
            });
        }

        // the scenario engine's overhead: capability sampling at fleet
        // scale, and a dropout/straggler ZO round vs the binary row above
        let cost = zowarmup::comm::CostModel::generic(175_258, 64);
        let spectrum = zowarmup::sim::Scenario::preset("edge-spectrum").unwrap();
        b.iter("sample_profiles K=1000 (edge-spectrum)", || {
            black_box(spectrum.sample_profiles(1000, 0, 7, &cost));
        });
        {
            let mut c = cfg.clone();
            c.scenario = zowarmup::sim::Scenario::preset("stragglers").unwrap();
            let shards = shards_from_partition(&src, &part);
            let init = ParamVec::zeros(be.dim());
            let mut fed =
                Federation::new(c, &be, shards, test_src.clone(), init).unwrap();
            b.iter("zo_round Q=8 stragglers (drops mid-round)", || {
                black_box(fed.zo_round().unwrap());
            });
        }

        // the buffered-async engine: rounds/second of the event-driven
        // fold (dispatch + heap + staleness-weighted aggregation) vs the
        // barrier rows above, at the same fleet shape
        for threads in [1usize, 4] {
            let mut c = cfg.clone();
            c.threads = threads;
            c.engine = zowarmup::config::EngineKind::Async;
            c.async_zo.buffer_k = 4;
            c.scenario = zowarmup::sim::Scenario::preset("edge-spectrum").unwrap();
            let shards = shards_from_partition(&src, &part);
            let init = ParamVec::zeros(be.dim());
            let mut fed =
                Federation::new(c, &be, shards, test_src.clone(), init).unwrap();
            b.iter(&format!("async_zo_round k=4 (edge-spectrum) threads={threads}"), || {
                black_box(fed.async_zo_round().unwrap());
            });
        }

        // the fleet-scale tentpole: O(sampled) ZO rounds over lazy
        // populations — the N=1e3 and N=1e7 rows must land within noise
        // of each other, because nothing in a round is O(N)
        for n_clients in [1_000usize, 10_000_000] {
            let mut c = cfg.clone();
            c.clients = n_clients;
            c.sample_zo = 64;
            c.population = zowarmup::config::PopulationMode::Lazy;
            c.scenario = zowarmup::sim::Scenario::preset("fleet").unwrap();
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new_lazy(
                c,
                &be,
                src.clone(),
                test_src.clone(),
                init,
            )
            .unwrap();
            let label = if n_clients == 1_000 {
                "zo_round N=1e3 K=64"
            } else {
                "zo_round N=1e7 K=64"
            };
            b.iter(label, || {
                black_box(fed.zo_round().unwrap());
            });
        }

        // the two-tier topology's overhead: the keyed edge partition,
        // the per-edge partial fold + in-order merge, and the per-edge
        // ledger rows on top of the flat fold — must land within noise
        // of the N=1e3 flat row above (the fold output is bit-identical;
        // only the grouping and attribution are extra work)
        {
            let mut c = cfg.clone();
            c.clients = 1_000;
            c.sample_zo = 64;
            c.edges = 16;
            c.population = zowarmup::config::PopulationMode::Lazy;
            c.scenario = zowarmup::sim::Scenario::preset("fleet").unwrap();
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new_lazy(
                c,
                &be,
                src.clone(),
                test_src.clone(),
                init,
            )
            .unwrap();
            b.iter("zo_round N=1e3 K=64 E=16 (two-tier)", || {
                black_box(fed.zo_round().unwrap());
            });
        }

        // adaptive probe budgets: the planner's O(Q log S) inversion plus
        // the heterogeneous-S round itself, vs the uniform row above
        {
            let mut c = cfg.clone();
            c.scenario = zowarmup::sim::Scenario::preset("edge-spectrum").unwrap();
            c.zo.adaptive_s = true;
            c.zo.s_min = 1;
            c.zo.s_max = 16;
            let shards = shards_from_partition(&src, &part);
            let init = ParamVec::zeros(be.dim());
            let mut fed =
                Federation::new(c, &be, shards, test_src.clone(), init).unwrap();
            b.iter("zo_round Q=8 adaptive-S edge-spectrum", || {
                black_box(fed.zo_round().unwrap());
            });
            let all: Vec<usize> = (0..8).collect();
            b.iter("planned_seed_counts K=8 (planner only)", || {
                black_box(fed.planned_seed_counts(&all));
            });
        }
    }

    b.report();
    if let Err(e) = b.write_json("runs/BENCH_fed_primitives.json") {
        eprintln!("bench json: {e}");
    }
}
