//! Bench: one end-to-end timing per paper table/figure regenerator
//! (DESIGN.md §4) at smoke scale. Each case runs the same code path as
//! `zowarmup exp <id>`; the printed rows ARE a miniature of the paper's
//! artifact, so this doubles as a regression gate on the harness.
//!
//! XLA-backed experiments (table5, fig5) and table1's manifest section are
//! skipped gracefully when artifacts/ is absent.

use zowarmup::config::Scale;
use zowarmup::exp;
use zowarmup::sim::Scenario;
use zowarmup::util::bench::Bench;

fn main() {
    let mut b = Bench::slow("paper_tables_smoke");
    b.min_iters = 1;
    b.warmup_iters = 0;

    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    for id in exp::ALL_IDS {
        if !have_artifacts && (id == "table5" || id == "fig5") {
            eprintln!("[skip] {id}: artifacts/ missing (run `make artifacts`)");
            continue;
        }
        let mut report = String::new();
        b.iter(&format!("exp {id} (smoke)"), || {
            report = exp::run(id, Scale::Smoke, "artifacts", &Scenario::default()).unwrap_or_else(|e| {
                panic!("exp {id} failed: {e:#}");
            });
        });
        // echo the table itself so `cargo bench` output contains the rows
        println!("{report}");
    }

    b.report();
}
